"""Chunk/extent primitives and the VMM device model.

GMLake's physical unit is a fixed-size chunk (2 MB in the paper, §3.1). On
GPU these are physical pages created by ``cuMemCreate``; on TPU we adapt them
to slots of a pre-reserved HBM arena (see DESIGN.md §2). This module holds:

  * the chunk-size constants and rounding helpers,
  * ``Extent`` — a run of consecutive chunk ids (the unit of the extent
    tables consumed by the Pallas stitch kernels),
  * ``VMMDevice`` — a device model that tracks physical-chunk inventory and
    charges per-API costs calibrated from the paper's own measurements
    (Table 1 / Fig. 6), in units of one ``cuMalloc`` call.

The device model is what lets the benchmarks regenerate the paper's latency
microbenchmarks on hardware that has no CUDA driver.
"""

from __future__ import annotations

import itertools
import json
import math
import random
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

try:  # the array fast paths need numpy; the scalar paths must not
    import numpy as np
except ImportError:  # pragma: no cover - exercised via subprocess guard test
    np = None

MB = 1024 * 1024
GB = 1024 * MB

#: GMLake uses a uniform 2 MB chunk (paper §3.1).
CHUNK_SIZE = 2 * MB

#: Requests below one chunk fall through to the splitting (caching) pool.
SMALL_ALLOC_LIMIT = CHUNK_SIZE

#: "minimal fragmentation limit ... (e.g., 128 MB)" — paper §4.2.3.
DEFAULT_FRAG_LIMIT = 128 * MB


def round_up(size: int, granularity: int = CHUNK_SIZE) -> int:
    if size <= 0:
        raise ValueError(f"allocation size must be positive, got {size}")
    return ((size + granularity - 1) // granularity) * granularity


def num_chunks(size: int) -> int:
    return round_up(size) // CHUNK_SIZE


@dataclass(frozen=True)
class Extent:
    """A run of ``n`` consecutive chunks starting at chunk id ``start``.

    Extent tables (lists of extents) are the TPU-side replacement for the
    GPU's VA->PA page mapping: the Pallas kernels walk them with scalar
    prefetch to issue chunk-granular DMA.
    """

    start: int
    n: int

    def __post_init__(self) -> None:
        if self.start < 0 or self.n <= 0:
            raise ValueError(f"bad extent ({self.start}, {self.n})")

    @property
    def stop(self) -> int:
        return self.start + self.n

    @property
    def nbytes(self) -> int:
        return self.n * CHUNK_SIZE


class ChunkRun:
    """An immutable view over a slice of a chunk-id list — O(1) splits.

    GMLake's Split divides a pBlock's ordered chunk list; copying the two
    halves is O(chunks) per split (pBlocks span up to ~1600 chunks on the
    serving traces). ``ChunkRun`` shares the backing list instead: slicing
    returns a new view over the same storage, so Split's chunk bookkeeping
    is O(1) regardless of block size. The backing list is never mutated —
    Alloc creates it, Split only ever narrows views — which is what makes
    sharing safe. Views compare equal to any sequence with the same ids,
    so consumers (extent packing, kernels, tests) treat them as lists.
    """

    __slots__ = ("base", "start", "stop", "_arr")

    def __init__(self, base: List[int], start: int = 0, stop: Optional[int] = None):
        self.base = base
        self.start = start
        self.stop = len(base) if stop is None else stop
        self._arr = None

    def asarray(self):
        """The view's ids as an int64 array (cached — the backing list is
        immutable by the ChunkRun contract, so the array can never go
        stale). Requires numpy; the scalar paths never call this."""
        arr = self._arr
        if arr is None:
            arr = np.asarray(self.base[self.start : self.stop], dtype=np.int64)
            self._arr = arr
        return arr

    def __len__(self) -> int:
        return self.stop - self.start

    def __iter__(self):
        if self.start == 0 and self.stop == len(self.base):
            return iter(self.base)
        return iter(self.base[self.start : self.stop])

    def __getitem__(self, i):
        if isinstance(i, slice):
            start, stop, step = i.indices(len(self))
            if step != 1:
                return self.base[self.start + start : self.start + stop : step]
            return ChunkRun(self.base, self.start + start, self.start + stop)
        n = len(self)
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError("ChunkRun index out of range")
        return self.base[self.start + i]

    def __eq__(self, other) -> bool:
        if isinstance(other, ChunkRun):
            return list(self) == list(other)
        if isinstance(other, (list, tuple)):
            return list(self) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"ChunkRun({list(self)!r})"


def _pack_ids_array(a) -> List[Extent]:
    """Vectorized run-length compression of an int64 id array: one compare
    finds every run break, the Extents are read off the break positions.
    Output is identical to the scalar scan — same runs, same order."""
    n = len(a)
    breaks = np.flatnonzero(a[1:] != a[:-1] + 1) + 1
    starts = np.concatenate(([0], breaks))
    stops = np.concatenate((breaks, [n]))
    return [
        Extent(int(a[s]), int(e - s))
        for s, e in zip(starts.tolist(), stops.tolist())
    ]


def pack_extents(chunk_ids: Iterable[int]) -> List[Extent]:
    """Compress an ordered chunk-id list into maximal consecutive runs."""
    if np is not None:
        if isinstance(chunk_ids, ChunkRun):
            a = chunk_ids.asarray()
        else:
            a = np.fromiter(chunk_ids, dtype=np.int64)
        if len(a):
            return _pack_ids_array(a)
        return []
    out: List[Extent] = []
    for cid in chunk_ids:
        if out and cid == out[-1].stop:
            out[-1] = Extent(out[-1].start, out[-1].n + 1)
        else:
            out.append(Extent(cid, 1))
    return out


def pack_extent_runs(chunk_runs: Iterable[Iterable[int]]) -> List[Extent]:
    """``pack_extents`` over a sequence of chunk-id runs without concatenating.

    Runs merge across boundaries exactly as if the ids were one flat list —
    this is the extent-table builder for stitched blocks, whose chunk ids
    live in per-member lists. With numpy, member ChunkRuns contribute their
    cached id arrays and one concatenate feeds the vectorized packer.
    """
    if np is not None:
        parts = [
            r.asarray() if isinstance(r, ChunkRun) else np.fromiter(r, dtype=np.int64)
            for r in chunk_runs
        ]
        parts = [p for p in parts if len(p)]
        if not parts:
            return []
        return _pack_ids_array(parts[0] if len(parts) == 1 else np.concatenate(parts))
    return pack_extents(itertools.chain.from_iterable(chunk_runs))


def unpack_extents(extents: Iterable[Extent]) -> List[int]:
    out: List[int] = []
    for e in extents:
        out.extend(range(e.start, e.stop))
    return out


# ---------------------------------------------------------------------------
# VMM cost model (paper Table 1 / Fig. 6)
# ---------------------------------------------------------------------------

# Per-allocation totals from Table 1: allocating 2 GB out of chunks of the
# given size, normalized to one cuMalloc call of the full 2 GB. We divide by
# the number of per-chunk calls to get per-call costs and interpolate in
# log-log space for intermediate chunk sizes.
_TABLE1_CHUNK_SIZES = (2 * MB, 128 * MB, 1024 * MB)
_TABLE1_CALLS = tuple(2 * GB // s for s in _TABLE1_CHUNK_SIZES)  # (1024, 16, 2)
_TABLE1_TOTALS = {
    # api: totals at chunk sizes 2MB / 128MB / 1024MB (in cuMalloc units)
    "cuMemAddressReserve": (0.003, 0.003, 0.002),  # one call per allocation
    "cuMemCreate": (18.1, 0.89, 0.79),
    "cuMemMap": (0.70, 0.01, 0.002),
    "cuMemSetAccess": (96.8, 8.2, 0.7),
}

#: cuMalloc / cuFree cost: the unit. cudaFree additionally synchronizes the
#: device; the ~10x end-to-end gap between the native allocator and the
#: caching allocator (paper §2.2) comes from those synchronizations stalling
#: pending kernels, which we fold into a sync surcharge.
CUMALLOC_COST = 1.0
CUFREE_COST = 1.0
DEVICE_SYNC_COST = 4.0


@lru_cache(maxsize=None)
def _per_call_cost(api: str, chunk_size: int) -> float:
    """Pure log-log interpolation of Table 1; cached — it sits on the
    per-allocation ledger path and only ever sees a handful of chunk sizes."""
    totals = _TABLE1_TOTALS[api]
    if api == "cuMemAddressReserve":
        # one call regardless of chunking; interpolate the totals directly
        per = totals
        calls = (1, 1, 1)
    else:
        per = tuple(t / c for t, c in zip(totals, _TABLE1_CALLS))
        calls = _TABLE1_CALLS
    xs = [math.log(s) for s in _TABLE1_CHUNK_SIZES]
    ys = [math.log(p) for p in per]
    x = math.log(min(max(chunk_size, _TABLE1_CHUNK_SIZES[0]), _TABLE1_CHUNK_SIZES[-1]))
    # piecewise-linear in log-log space
    if x <= xs[1]:
        t = (x - xs[0]) / (xs[1] - xs[0])
        y = ys[0] + t * (ys[1] - ys[0])
    else:
        t = (x - xs[1]) / (xs[2] - xs[1])
        y = ys[1] + t * (ys[2] - ys[1])
    return math.exp(y)


@dataclass
class VMMCostLedger:
    """Accumulated modeled device-API cost, in cuMalloc units."""

    by_api: dict = field(default_factory=dict)

    def charge(self, api: str, cost: float, calls: int = 1) -> None:
        entry = self.by_api.setdefault(api, [0.0, 0])
        entry[0] += cost
        entry[1] += calls

    @property
    def total(self) -> float:
        return sum(v[0] for v in self.by_api.values())

    @property
    def total_calls(self) -> int:
        return sum(v[1] for v in self.by_api.values())

    def snapshot(self) -> dict:
        return {k: tuple(v) for k, v in self.by_api.items()}


class DeviceOOM(MemoryError):
    """Raised by the device model when physical capacity is exhausted."""


class TransientDeviceError(DeviceOOM):
    """An injected *transient* VMM API failure (see ``FaultInjector``).

    Subclasses ``DeviceOOM`` so every existing ``except DeviceOOM`` site
    still contains it — a fault can never escape a backend as a raw device
    error — while recovery-aware backends can distinguish a retryable
    driver hiccup from genuine capacity exhaustion.
    """


class VMMDevice:
    """Physical-memory inventory + API cost model.

    Models a device with ``capacity_bytes`` of HBM, handing out 2 MB
    physical chunks (``cu_mem_create``) or classic contiguous segments
    (``cu_malloc``). Contiguity of chunk ids is *not* guaranteed — freed
    chunks are recycled LIFO, exactly the property that forces stitching.
    """

    def __init__(self, capacity_bytes: int, chunk_size: int = CHUNK_SIZE):
        if capacity_bytes % chunk_size:
            raise ValueError("capacity must be a multiple of the chunk size")
        self.capacity_bytes = capacity_bytes
        self.chunk_size = chunk_size
        self.total_chunks = capacity_bytes // chunk_size
        self._free_chunks: List[int] = list(range(self.total_chunks - 1, -1, -1))
        self._segment_bytes = 0  # bytes held by cu_malloc segments
        self.ledger = VMMCostLedger()
        self._next_va = 0
        # capacity-shrink accounting (simulated device loss, see shrink())
        self._pending_shrink_chunks = 0
        self.shrunk_bytes = 0

    # -- accounting ---------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        chunk_bytes = (self.total_chunks - len(self._free_chunks)) * self.chunk_size
        return chunk_bytes + self._segment_bytes

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes

    def shrink(self, nbytes: int) -> int:
        """Permanently lose ``nbytes`` of capacity (device loss / neighbor-
        tenant pressure).

        Free chunks are confiscated immediately; when the free inventory
        cannot cover the loss, the remainder becomes a *pending* debt that
        is retired by future ``cu_mem_release`` calls — the tenant reclaims
        physical pages as the allocator hands them back. While the debt is
        outstanding ``free_bytes`` may go negative and every alloc-side API
        fails, which is exactly the pressure a recovery ladder must relieve
        by releasing memory. Returns the pending (not yet retired) bytes.
        """
        n = round_up(nbytes, self.chunk_size) // self.chunk_size
        take = min(n, len(self._free_chunks))
        # confiscate from the bottom of the LIFO stack so the recycling
        # order of the surviving free chunks is undisturbed
        del self._free_chunks[:take]
        self.total_chunks -= take
        self.capacity_bytes -= n * self.chunk_size
        self._pending_shrink_chunks += n - take
        self.shrunk_bytes += n * self.chunk_size
        return self._pending_shrink_chunks * self.chunk_size

    # -- native allocator path ---------------------------------------------
    def cu_malloc(self, size: int) -> int:
        """Classic cudaMalloc: contiguous segment, charged 1 unit (+sync)."""
        size = round_up(size, self.chunk_size)
        if size > self.free_bytes:
            raise DeviceOOM(f"cuMalloc({size}) with {self.free_bytes} free")
        self._segment_bytes += size
        self.ledger.charge("cuMalloc", CUMALLOC_COST)
        va = self._next_va
        self._next_va += size
        return va

    def cu_free(self, size: int, *, synchronize: bool = True) -> None:
        size = round_up(size, self.chunk_size)
        self._segment_bytes -= size
        assert self._segment_bytes >= 0
        cost = CUFREE_COST + (DEVICE_SYNC_COST if synchronize else 0.0)
        self.ledger.charge("cuFree", cost)

    # -- low-level VMM path ---------------------------------------------------
    def cu_mem_address_reserve(self, size: int) -> int:
        self.ledger.charge(
            "cuMemAddressReserve", _per_call_cost("cuMemAddressReserve", self.chunk_size)
        )
        va = self._next_va
        self._next_va += round_up(size, self.chunk_size)
        return va

    def cu_mem_create(self, n: int) -> List[int]:
        """Create ``n`` physical chunks; ids are NOT contiguous in general.

        The free-chunk inventory alone is not the capacity check: segment
        bytes held via ``cu_malloc`` never leave the chunk pool, so a
        backend mixing large segments with VMM chunks (ellm's elastic
        arena atop a GMLake core) could otherwise reserve past physical
        capacity. Chunk creation therefore also respects ``free_bytes``.
        """
        if n > len(self._free_chunks) or n * self.chunk_size > self.free_bytes:
            raise DeviceOOM(
                f"cuMemCreate({n} chunks) with {len(self._free_chunks)} free "
                f"chunks, {self.free_bytes} free bytes"
            )
        if n:
            # one slice + delete instead of n pops; a reversed tail is
            # exactly the pop sequence, so recycling order is unchanged
            chunks = self._free_chunks[-n:]
            del self._free_chunks[-n:]
            chunks.reverse()
        else:
            chunks = []
        self.ledger.charge("cuMemCreate", n * _per_call_cost("cuMemCreate", self.chunk_size), n)
        return chunks

    def cu_mem_map(self, n: int) -> None:
        self.ledger.charge("cuMemMap", n * _per_call_cost("cuMemMap", self.chunk_size), n)

    def cu_mem_set_access(self, n: int) -> None:
        self.ledger.charge(
            "cuMemSetAccess", n * _per_call_cost("cuMemSetAccess", self.chunk_size), n
        )

    def cu_mem_unmap(self, n: int) -> None:
        self.ledger.charge("cuMemUnmap", n * 0.01, n)

    def cu_mem_release(self, chunks: Iterable[int]) -> None:
        chunks = list(chunks)
        ncalls = len(chunks)
        if self._pending_shrink_chunks:
            # retire outstanding shrink debt before refilling the free list:
            # the confiscating tenant takes pages the moment they come back
            retired = min(self._pending_shrink_chunks, ncalls)
            self._pending_shrink_chunks -= retired
            self.total_chunks -= retired
            chunks = chunks[retired:]
        self._free_chunks.extend(chunks)
        self.ledger.charge("cuMemRelease", ncalls * 0.01, ncalls)

    def cu_mem_address_free(self) -> None:
        self.ledger.charge("cuMemAddressFree", 0.003)

    # -- composite helpers ----------------------------------------------------
    def vmm_alloc(self, size: int) -> List[int]:
        """Reserve + create + map + set-access for one block. Returns chunks."""
        n = num_chunks(size)
        self.cu_mem_address_reserve(size)
        chunks = self.cu_mem_create(n)
        self.cu_mem_map(n)
        self.cu_mem_set_access(n)
        return chunks

    def vmm_map_existing(self, n: int) -> None:
        """Stitch: reserve a VA and re-map ``n`` already-created chunks."""
        self.cu_mem_address_reserve(n * self.chunk_size)
        self.cu_mem_map(n)
        self.cu_mem_set_access(n)

    def vmm_split_remap(self, na: int, nb: int) -> None:
        """Split: re-map both halves (``na`` + ``nb`` chunks) of one block.

        Deliberately issues the exact call sequence of two
        ``vmm_map_existing`` calls: batching the charges into one ledger
        update per API would change floating-point summation order and
        break the bit-identity of ``model_cost`` across rounds — the
        load-independent signal the replay regression gate keys on.
        """
        self.vmm_map_existing(na)
        self.vmm_map_existing(nb)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultWindow:
    """A bounded interval of elevated fault pressure.

    Windows are indexed in 1-based alloc-side device calls (the same clock
    as ``shrink_at_call``) and cover ``[start_call, start_call + duration)``.
    While a window is active its probabilities *override* the schedule's
    base rates wherever they are higher (``max`` composition), so several
    overlapping windows model correlated storms without double-drawing.
    """

    start_call: int
    duration: int
    create_fail_prob: float = 0.0
    map_fail_prob: float = 0.0
    release_fail_prob: float = 0.0
    slow_prob: float = 0.0

    def active_at(self, call: int) -> bool:
        return self.start_call <= call < self.start_call + self.duration


@dataclass(frozen=True)
class PreemptionEvent:
    """One row of the checked-in preemption-trace format.

    ``at`` is the event time in alloc-side device calls (1-based, the
    injector's deterministic clock); ``severity`` is a kind-specific
    magnitude in [0, 1]; ``duration`` is the event's window length in
    calls. Kinds:

      * ``revocation``    — spot-style instance revocation: a warning
        brownout window ``lead`` calls ahead of ``at`` (checkpoint
        pressure), then a capacity loss of ``severity x capacity`` plus a
        deterministic transient burst over the revocation window;
      * ``capacity_loss`` — plain shrink of ``severity x capacity`` (a
        cluster of these close together is a correlated loss storm);
      * ``transient``     — flurry window: elevated transient-failure
        probability (create/map/release sides) of ``severity``;
      * ``brownout``      — slow-device window: slow-call probability of
        ``severity``, no failures.
    """

    at: int
    kind: str
    severity: float
    duration: int = 1
    #: warning lead time (calls) before a revocation; ignored elsewhere
    lead: int = 0

    KINDS = ("revocation", "capacity_loss", "transient", "brownout")

    def __post_init__(self) -> None:
        if self.kind not in self.KINDS:
            raise ValueError(
                f"unknown preemption event kind {self.kind!r}; "
                f"expected one of {self.KINDS}"
            )
        if self.at < 1 or self.duration < 1:
            raise ValueError(f"bad preemption event timing ({self.at}, {self.duration})")
        if not 0.0 <= self.severity <= 1.0:
            raise ValueError(f"severity must be in [0, 1], got {self.severity}")


#: the checked-in preemption trace format tag (see tests/data/)
PREEMPTION_TRACE_FORMAT = "repro.preemption.v1"


def load_preemption_trace(source) -> List[PreemptionEvent]:
    """Parse a ``repro.preemption.v1`` trace into ``PreemptionEvent`` rows.

    ``source`` is a path to a JSON file, an already-parsed payload dict,
    or a bare event list (dicts or ``PreemptionEvent`` instances pass
    through). The format is deliberately tiny — event time, kind,
    severity, duration — so real spot-market / maintenance preemption
    logs reduce to it with a one-line converter.
    """
    if isinstance(source, (str,)) or hasattr(source, "read_text"):
        with open(source) as f:
            source = json.load(f)
    if isinstance(source, dict):
        fmt = source.get("format")
        if fmt != PREEMPTION_TRACE_FORMAT:
            raise ValueError(
                f"unknown preemption trace format {fmt!r}; "
                f"expected {PREEMPTION_TRACE_FORMAT!r}"
            )
        source = source["events"]
    out: List[PreemptionEvent] = []
    for ev in source:
        if isinstance(ev, PreemptionEvent):
            out.append(ev)
        else:
            out.append(PreemptionEvent(
                at=int(ev["at"]),
                kind=str(ev["kind"]),
                severity=float(ev["severity"]),
                duration=int(ev.get("duration", 1)),
                lead=int(ev.get("lead", 0)),
            ))
    return sorted(out, key=lambda e: (e.at, e.kind))


@dataclass(frozen=True)
class FaultSchedule:
    """Deterministic fault plan for a :class:`FaultInjector`.

    All randomness comes from ``random.Random(seed)`` drawn in device-API
    call order, so the same allocator run over the same schedule observes
    the same faults — replays, tests and benchmarks are reproducible.
    """

    seed: int = 0
    #: per-call probability that an alloc-side API (``cuMalloc`` /
    #: ``cuMemCreate``) fails transiently
    create_fail_prob: float = 0.0
    #: per-call probability that ``cuMemMap`` fails transiently
    map_fail_prob: float = 0.0
    #: consecutive failures per triggered fault (a flaky driver rarely
    #: fails exactly once)
    burst: int = 1
    #: driver-level retries absorbed per cuMemMap fault before the error
    #: propagates; keep >= ``burst`` or a mid-stitch map can fail
    #: non-transactionally (see FaultInjector docstring)
    map_retry_limit: int = 8
    #: per-call probability of a slow device call; the stall is charged to
    #: the ledger under ``faultStall``
    slow_prob: float = 0.0
    slow_cost: float = DEVICE_SYNC_COST
    #: one-shot capacity loss fired entering the Nth alloc-side call
    #: (1-based; None = never) — simulated device loss / tenant pressure
    shrink_at_call: Optional[int] = None
    shrink_bytes: int = 0
    #: one-shot deterministic failure burst armed entering the Nth
    #: alloc-side call (1-based; None = never): the next ``fail_burst``
    #: alloc-side calls raise ``TransientDeviceError`` regardless of the
    #: probability schedule. Sized past a backend's recovery-ladder
    #: attempt budget this reproducibly forces the AllocatorOOM ->
    #: supervisor-restore path (the kill/recover scenario)
    fail_at_call: Optional[int] = None
    fail_burst: int = 0
    #: per-call probability that a release-side API (``cuMemRelease`` /
    #: ``cuMemUnmap``) faults transiently. Release-side faults are always
    #: *absorbed* at the injector (bounded retries, each charged as
    #: ``faultStall``) — free/drain paths are fire-and-forget in every
    #: backend, so an exception there would corrupt allocator state
    #: instead of exercising recovery. The counters/ledger still record
    #: every fault, which is what the chaos verdicts assert on.
    release_fail_prob: float = 0.0
    #: stall-charged retries per release-side fault before the injector
    #: gives up stalling and lets the call complete
    release_retry_limit: int = 4
    #: additional capacity losses beyond ``shrink_at_call``:
    #: ``((call, bytes), ...)`` — multi-event chaos schedules need more
    #: than the legacy one-shot knob
    shrinks: Tuple[Tuple[int, int], ...] = ()
    #: additional deterministic failure bursts: ``((call, n), ...)``
    bursts_at: Tuple[Tuple[int, int], ...] = ()
    #: bounded windows of elevated fault pressure (see ``FaultWindow``)
    windows: Tuple[FaultWindow, ...] = ()

    # -- preemption-trace synthesis ----------------------------------------
    #: from_preemption_trace: transient-burst length per unit severity of a
    #: revocation (sized so severity ~0.5 exceeds one ladder's retry budget)
    REVOCATION_BURST_SCALE = 24
    #: warning-window slow probability per unit severity
    WARNING_SLOW_PROB = 0.5

    @classmethod
    def from_preemption_trace(
        cls,
        events: Union[str, Sequence],
        *,
        capacity_bytes: int,
        seed: int = 0,
        **overrides,
    ) -> "FaultSchedule":
        """Synthesize a multi-event schedule from a preemption trace.

        ``events`` is anything ``load_preemption_trace`` accepts (a path
        to a checked-in ``repro.preemption.v1`` file, a payload dict, or
        an event list). ``capacity_bytes`` scales each event's severity
        into a concrete byte loss; chunk-quantization happens in
        ``VMMDevice.shrink``. The synthesis is pure — the same trace,
        seed and capacity always yield the same (hashable, frozen)
        schedule — so chaos campaigns are replayable end to end.
        """
        evs = load_preemption_trace(events)
        shrinks: List[Tuple[int, int]] = []
        bursts: List[Tuple[int, int]] = []
        windows: List[FaultWindow] = []
        for ev in evs:
            if ev.kind == "revocation":
                if ev.lead > 0:
                    # the warning: a pre-revocation brownout (checkpoint
                    # pressure in a real fleet shows up as device stalls)
                    start = max(1, ev.at - ev.lead)
                    windows.append(FaultWindow(
                        start_call=start, duration=ev.at - start,
                        slow_prob=cls.WARNING_SLOW_PROB * ev.severity,
                    ))
                shrinks.append((ev.at, int(ev.severity * capacity_bytes)))
                bursts.append(
                    (ev.at, max(1, int(ev.severity * cls.REVOCATION_BURST_SCALE)))
                )
                windows.append(FaultWindow(
                    start_call=ev.at, duration=ev.duration,
                    create_fail_prob=min(1.0, 0.5 * ev.severity),
                ))
            elif ev.kind == "capacity_loss":
                shrinks.append((ev.at, int(ev.severity * capacity_bytes)))
            elif ev.kind == "transient":
                windows.append(FaultWindow(
                    start_call=ev.at, duration=ev.duration,
                    create_fail_prob=ev.severity,
                    map_fail_prob=0.5 * ev.severity,
                    release_fail_prob=0.5 * ev.severity,
                ))
            else:  # brownout
                windows.append(FaultWindow(
                    start_call=ev.at, duration=ev.duration,
                    slow_prob=ev.severity,
                ))
        kw = dict(
            seed=seed,
            shrinks=tuple(shrinks),
            bursts_at=tuple(bursts),
            windows=tuple(windows),
        )
        kw.update(overrides)
        return cls(**kw)


class FaultInjector:
    """Seed-scheduled fault-injecting wrapper around a :class:`VMMDevice`.

    A drop-in ``device`` for every backend: anything not overridden
    delegates to the wrapped device (``__getattr__``), so ledgers, capacity
    accounting and the native path behave identically. What it injects:

      * alloc-side APIs (``cu_malloc``, ``cu_mem_create``) raise
        :class:`TransientDeviceError` per the probability/burst schedule,
        and fire the scheduled capacity shrinks (the legacy one-shot knobs
        plus any ``shrinks``/``bursts_at``/``windows`` multi-event rows);
      * ``cu_mem_map`` faults are absorbed by a bounded driver-level retry
        loop, each absorbed fault charged to the ledger as ``faultStall``.
        Retrying at the injector (not the backend) keeps mid-stitch /
        mid-split map failures crash-consistent: GMLake mutates its
        registries before remapping, so a map error escaping there would
        corrupt allocator state rather than exercise recovery;
      * release-side APIs (``cu_mem_release``, ``cu_mem_unmap``) fault per
        ``release_fail_prob`` but are *always absorbed*: free and
        deferred-unmap drains are fire-and-forget in every backend, so the
        injector stalls (bounded by ``release_retry_limit``, charged as
        ``faultStall``) and then lets the call complete. The fault
        counters and ledger record every hit, which is how chaos verdicts
        see the drain path exercised under failure;
      * ``vmm_alloc`` is transactional: if mapping fails past the retry
        limit after chunks were created, the chunks are released before the
        error propagates — the backend sees the fault at a safe point and
        its recovery ladder takes over;
      * slow-call injection charges ``faultStall`` without failing.

    Backends auto-detect the wrapper via ``supports_fault_injection`` and
    enable their recovery ladder, keeping the fault-free default path
    bit-identical to the legacy one.
    """

    supports_fault_injection = True

    def __init__(
        self,
        device: VMMDevice,
        schedule: FaultSchedule = FaultSchedule(),
        *,
        external_clock: bool = False,
    ):
        self.inner = device
        self.schedule = schedule
        # external_clock: the fault clock is advanced by the driver (one
        # ``tick()`` per *client* allocation) instead of per device call.
        # Caching backends absorb almost every device call — a replayed
        # workload can reach the device once for hundreds of client
        # mallocs — so trace offsets authored in client-call time would
        # otherwise never fire against them.
        self.external_clock = external_clock
        self._rng = random.Random(schedule.seed)
        self._alloc_calls = 0
        self._burst_left = 0  # alloc-side burst in progress
        self._map_burst_left = 0
        self._release_burst_left = 0
        self.fault_counts: Dict[str, int] = {}
        self.fault_events: List[dict] = []
        # multi-event rows folded in with the legacy one-shot knobs; the
        # dicts key on the 1-based alloc-side call counter
        self._shrinks: Dict[int, int] = {
            call: nbytes for call, nbytes in schedule.shrinks
        }
        if schedule.shrink_at_call is not None and schedule.shrink_bytes:
            self._shrinks[schedule.shrink_at_call] = (
                self._shrinks.get(schedule.shrink_at_call, 0)
                + schedule.shrink_bytes
            )
        self._armed_bursts: Dict[int, int] = {
            call: n for call, n in schedule.bursts_at
        }
        if schedule.fail_at_call is not None and schedule.fail_burst:
            self._armed_bursts[schedule.fail_at_call] = max(
                self._armed_bursts.get(schedule.fail_at_call, 0),
                schedule.fail_burst,
            )

    # -- window composition ---------------------------------------------------
    def _prob(self, field: str) -> float:
        """Effective probability of ``field`` at the current alloc-side
        call: the schedule's base rate, raised by any active window."""
        p = getattr(self.schedule, field)
        for w in self.schedule.windows:
            if w.active_at(self._alloc_calls):
                wp = getattr(w, field)
                if wp > p:
                    p = wp
        return p

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __repr__(self) -> str:
        return f"FaultInjector({self.inner!r}, {self.schedule!r})"

    # -- bookkeeping ----------------------------------------------------------
    def _note(self, kind: str, **detail) -> None:
        self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1
        ev = {"kind": kind, "call": self._alloc_calls}
        ev.update(detail)
        self.fault_events.append(ev)

    def _maybe_slow(self) -> None:
        s = self.schedule
        p = self._prob("slow_prob")
        if p and self._rng.random() < p:
            self.inner.ledger.charge("faultStall", s.slow_cost)
            self._note("slow")

    def _advance_clock(self) -> None:
        """One step of the fault clock: apply any shrink or burst arming
        scheduled for the new call index. Never raises — clock-driven
        events take effect on the device (shrink) or arm state consumed
        by the next real device call (burst)."""
        self._alloc_calls += 1
        nbytes = self._shrinks.pop(self._alloc_calls, 0)
        if nbytes:
            pending = self.inner.shrink(nbytes)
            self._note("shrink", bytes=nbytes, pending=pending)
        armed = self._armed_bursts.pop(self._alloc_calls, 0)
        if armed:
            self._burst_left = armed
            self._note("burst_armed", n=armed)

    def tick(self) -> None:
        """Advance the external fault clock by one client allocation.

        Only meaningful with ``external_clock=True``: drivers that sit
        above a caching backend call this once per client malloc, so
        preemption-trace ``at`` offsets land in client-call time no
        matter how few device calls the backend actually issues. A
        burst armed here still strikes on the next genuine device call
        — a backend that serves the burst window entirely from cache
        legitimately never sees those faults."""
        if self.external_clock:
            self._advance_clock()

    def _alloc_side(self, api: str) -> None:
        s = self.schedule
        if not self.external_clock:
            self._advance_clock()
        self._maybe_slow()
        if self._burst_left:
            self._burst_left -= 1
            self._note("create_fault", api=api, burst=True)
            raise TransientDeviceError(f"injected transient {api} failure (burst)")
        p = self._prob("create_fail_prob")
        if p and self._rng.random() < p:
            self._burst_left = s.burst - 1
            self._note("create_fault", api=api, burst=False)
            raise TransientDeviceError(f"injected transient {api} failure")

    # -- injected primitives --------------------------------------------------
    def cu_malloc(self, size: int) -> int:
        self._alloc_side("cuMalloc")
        return self.inner.cu_malloc(size)

    def cu_mem_create(self, n: int) -> List[int]:
        self._alloc_side("cuMemCreate")
        return self.inner.cu_mem_create(n)

    def _map_fault(self) -> bool:
        """One cuMemMap draw; True = this call faults."""
        s = self.schedule
        self._maybe_slow()
        if self._map_burst_left:
            self._map_burst_left -= 1
            return True
        p = self._prob("map_fail_prob")
        if p and self._rng.random() < p:
            self._map_burst_left = s.burst - 1
            return True
        return False

    def cu_mem_map(self, n: int) -> None:
        s = self.schedule
        for attempt in range(s.map_retry_limit + 1):
            if not self._map_fault():
                if attempt:
                    self._note("map_retries_absorbed", retries=attempt)
                return self.inner.cu_mem_map(n)
            self._note("map_fault")
            self.inner.ledger.charge("faultStall", s.slow_cost)
        raise TransientDeviceError(
            f"injected cuMemMap failure persisted past {s.map_retry_limit} retries"
        )

    # -- release-side injection ----------------------------------------------
    def _release_fault(self) -> bool:
        """One release-side draw; True = this call faults (is stalled)."""
        if self._release_burst_left:
            self._release_burst_left -= 1
            return True
        p = self._prob("release_fail_prob")
        if p and self._rng.random() < p:
            self._release_burst_left = self.schedule.burst - 1
            return True
        return False

    def _release_side(self, api: str) -> None:
        """Absorb release-side faults: stall (bounded), never fail.

        Free and drain paths mutate backend registries *before* touching
        the device, so an exception here would corrupt allocator state
        rather than exercise recovery — and real streams retire unmaps
        asynchronously, where a transient driver error degrades to a
        stall, not a leak. The injector therefore charges each fault as a
        ``faultStall`` and retries; past ``release_retry_limit`` it stops
        stalling and completes the call, noting the exhaustion.
        """
        s = self.schedule
        if not s.release_fail_prob and not self._release_burst_left:
            has_window = any(
                w.release_fail_prob for w in s.windows
            )
            if not has_window:
                return  # fault-free fast path: zero draws, zero notes
        for attempt in range(s.release_retry_limit + 1):
            if not self._release_fault():
                if attempt:
                    self._note("release_retries_absorbed", api=api,
                               retries=attempt)
                return
            self._note("release_fault", api=api)
            self.inner.ledger.charge("faultStall", s.slow_cost)
        self._note("release_fault_exhausted", api=api)

    def cu_mem_release(self, chunks: Iterable[int]) -> None:
        self._release_side("cuMemRelease")
        return self.inner.cu_mem_release(chunks)

    def cu_mem_unmap(self, n: int) -> None:
        self._release_side("cuMemUnmap")
        return self.inner.cu_mem_unmap(n)

    def cu_free(self, size: int, *, synchronize: bool = True) -> None:
        # segment-granularity release — the path every caching-family
        # backend's release_cached walks; same absorb-and-stall contract
        # as the VMM-level release primitives
        self._release_side("cuFree")
        return self.inner.cu_free(size, synchronize=synchronize)

    # -- composite helpers ----------------------------------------------------
    # Re-declared so they route through the injector's primitives; the base
    # class's composites would call the wrapped device's own cu_* methods
    # and bypass injection entirely.
    def vmm_alloc(self, size: int) -> List[int]:
        n = num_chunks(size)
        self.cu_mem_address_reserve(size)
        chunks = self.cu_mem_create(n)
        try:
            self.cu_mem_map(n)
            self.cu_mem_set_access(n)
        except TransientDeviceError:
            # transactional: never leak created chunks on a map failure
            self.inner.cu_mem_release(chunks)
            raise
        return chunks

    def vmm_map_existing(self, n: int) -> None:
        self.cu_mem_address_reserve(n * self.chunk_size)
        self.cu_mem_map(n)
        self.cu_mem_set_access(n)

    def vmm_split_remap(self, na: int, nb: int) -> None:
        self.vmm_map_existing(na)
        self.vmm_map_existing(nb)
