"""String-keyed allocator backend registry.

Every backend registers itself at import time (``@register(...)`` on the
class); consumers look it up by name:

    from repro.alloc import registry
    allocator = registry.create("gmlake", device)

or hand any consumer the key directly — ``trace.replay(trace, "stalloc")``,
``Arena(cfg, allocator="caching")``, ``benchmarks/run.py --allocator
stalloc`` all resolve through here. Registering a new backend is one
decorator; nothing in the replay/serve/bench layers changes.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Type, Union

from .protocol import AllocatorCapabilities, AllocatorProtocol

#: name -> backend class. Insertion order is registration order; the
#: built-ins register caching, native, gmlake, stalloc (in module-import
#: order), so iteration is stable for tests and benchmark tables.
_BACKENDS: Dict[str, type] = {}


def register(
    name: str, capabilities: Optional[AllocatorCapabilities] = None
) -> Callable[[type], type]:
    """Class decorator: register an allocator backend under ``name``.

    The class must satisfy ``AllocatorProtocol`` and take
    ``(device, *, record_timeline=False, **backend_kwargs)``. If
    ``capabilities`` is not given, the class must carry its own
    ``capabilities`` class attribute.
    """

    def deco(cls: type) -> type:
        if capabilities is not None:
            cls.capabilities = capabilities
        if getattr(cls, "capabilities", None) is None:
            raise ValueError(f"backend {name!r} declares no capabilities")
        if name in _BACKENDS and _BACKENDS[name] is not cls:
            raise ValueError(f"backend name {name!r} already registered")
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def names() -> List[str]:
    """Registered backend names, registration order."""
    return list(_BACKENDS)


def get(name: str) -> type:
    """The backend class for ``name``; KeyError lists valid names."""
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown allocator backend {name!r}; registered: {', '.join(_BACKENDS)}"
        ) from None


def capabilities(backend: Union[str, AllocatorProtocol, type]) -> AllocatorCapabilities:
    """Capability flags for a backend name, class, or instance."""
    if isinstance(backend, str):
        backend = get(backend)
    return backend.capabilities


def with_capability(flag: str) -> List[str]:
    """Backend names whose declared capabilities set ``flag`` truthy.

    The generic way for consumers (fault benches, conformance tests) to
    select e.g. every ``recovery`` backend without hardcoding names.
    """
    return [n for n, cls in _BACKENDS.items() if getattr(cls.capabilities, flag, False)]


def create(name: str, device, record_timeline: bool = False, **kwargs):
    """Instantiate backend ``name`` over ``device``."""
    return get(name)(device, record_timeline=record_timeline, **kwargs)


def resolve(
    allocator: Union[str, AllocatorProtocol],
    device_factory: Callable[[], object],
    record_timeline: bool = False,
    **kwargs,
):
    """A backend instance from either a registry key or an instance.

    Strings construct a fresh backend over ``device_factory()``; instances
    pass through untouched (their device and options are already bound) —
    passing construction options alongside an instance is rejected rather
    than silently dropped. This is the one conversion point every
    backend-generic consumer uses.
    """
    if isinstance(allocator, str):
        return create(allocator, device_factory(), record_timeline, **kwargs)
    if record_timeline or kwargs:
        opts = ["record_timeline"] if record_timeline else []
        opts += sorted(kwargs)
        raise ValueError(
            f"allocator options {opts} were passed with an already-"
            f"constructed {allocator.name!r} instance; construct the "
            f"backend with them, or pass the registry key instead"
        )
    return allocator


__all__ = [
    "register",
    "names",
    "get",
    "capabilities",
    "with_capability",
    "create",
    "resolve",
]
