"""The allocator protocol: the contract every backend implements.

The paper frames GMLake as one point in a design space of allocators —
native (cudaMalloc/cudaFree), caching/BFC (PyTorch), VMS-stitching
(GMLake) — and the repo grows that space further (spatio-temporal
planning, and whatever comes next: sharded pools, async reclamation,
elastic serving policies). This module pins down the one surface they all
share, so every consumer (trace replay, the arena, the serving engine,
the benchmarks) is written once against the protocol and picks a backend
by registry key.

The contract, exactly as the replay loop exercises it:

  * ``malloc(size) -> Allocation`` — raises ``AllocatorOOM`` when the
    request cannot be satisfied; never returns None.
  * ``free(alloc)`` — accepts exactly the ``Allocation`` objects this
    allocator's ``malloc`` produced (``Allocation.owner`` routes frees in
    composite allocators).
  * ``stats`` — an ``AllocatorStats`` updated on every malloc/free.
  * ``reserved_bytes`` — bytes currently set aside from the device.
  * ``release_cached() -> int`` — return cached-but-unused memory to the
    device; returns bytes released (0 when the backend caches nothing).
  * ``check_invariants()`` — validate internal structure (test/debug).
  * ``capabilities`` — an ``AllocatorCapabilities`` describing what the
    backend can do, so generic consumers branch on declared capability
    instead of isinstance checks.

Backends that plan from a profiled trace (``capabilities.planning``)
additionally implement ``prepare(trace)``; the replay harness calls it
once, outside the timed loop, before feeding events.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # imported for annotations only: no import cycle at runtime
    from .caching_allocator import Allocation
    from .metrics import AllocatorStats


@dataclass(frozen=True)
class AllocatorCapabilities:
    """What a backend can do, declared up front.

    Consumers branch on these instead of isinstance checks, so a new
    backend never requires touching replay/arena/bench code.
    """

    #: keeps freed memory reserved for reuse (anything but native)
    caching: bool = True
    #: can hand out physically non-contiguous blocks (VMS stitching);
    #: implies blocks carry ``extents`` for the stitch kernels
    stitching: bool = False
    #: plans placements from a profiled trace: ``prepare(trace)`` must be
    #: called before replay (the harness does, outside the timed loop)
    planning: bool = False
    #: exposes GMLake-style ``state_counts`` (Algorithm 1 S1–S5 tallies)
    state_counts: bool = False
    #: ``release_cached()`` can actually return memory to the device
    releases_cached: bool = False
    #: walks the staged OOM-recovery ladder (release cached -> evict
    #: StitchFree VA -> drain deferred unmaps -> bounded retry) instead of
    #: surfacing the first ``DeviceOOM``; auto-enabled under a
    #: fault-injecting device, opt-in (``recovery=True``) otherwise
    recovery: bool = False
    #: elastically inflates/deflates its device reservation with demand
    #: (eLLM-style): grows the arena under pressure and — the honesty
    #: contract pinned by the conformance suite — shrinks it back after
    #: sustained deflation, without an explicit ``release_cached()`` call
    elastic: bool = False


@runtime_checkable
class AllocatorProtocol(Protocol):
    """Structural type for allocation backends (see module docstring).

    ``runtime_checkable`` only verifies method presence, not signatures —
    the behavioural contract is pinned by the conformance suite in
    ``tests/test_alloc_protocol.py``, which every registered backend runs.
    """

    name: str

    @property
    def stats(self) -> "AllocatorStats": ...  # noqa: E704

    def malloc(self, size: int) -> "Allocation": ...  # noqa: E704

    def free(self, alloc: "Allocation") -> None: ...  # noqa: E704

    @property
    def reserved_bytes(self) -> int: ...  # noqa: E704

    def release_cached(self) -> int: ...  # noqa: E704

    def check_invariants(self) -> None: ...  # noqa: E704


__all__ = ["AllocatorCapabilities", "AllocatorProtocol"]
