"""eLLM-style elastic serving allocator (after arXiv 2506.15155).

Serving workloads breathe: admission waves inflate the KV working set,
drain phases deflate it, and weight-class tensors (model shards, large
activations) come and go with tenant churn. A caching allocator keeps the
high-water reservation forever; GMLake keeps its physical chunks on
purpose (Update semantics). The eLLM observation is that the *reservation
itself* should be elastic — grow the arena under admission pressure,
shrink it back when sustained deflation shows the pressure is gone — so a
multi-tenant device can hand unused memory to the next tenant instead of
hoarding it.

This backend composes that idea with the repo's VMS stitching layer:

  * **Elastic weight arena** — requests at or above ``weight_threshold``
    are placed best-fit inside a slab-quantized arena of classic
    contiguous segments (``cu_malloc``). Inflation reserves whole slabs;
    a deflation governor watches arena utilization on every free and,
    after ``deflate_patience`` consecutive low-utilization events,
    releases every trailing slab above the live watermark back to the
    device — no ``release_cached()`` call required. That is the
    ``capabilities.elastic`` honesty contract the conformance suite pins.
  * **VMS stitching core under pressure** — KV-sized requests (below the
    threshold) and any weight request the device cannot cover with a
    contiguous slab run spill to an embedded ``GMLakeAllocator``, whose
    stitching absorbs exactly the fragmentation that elastic inflation
    would otherwise trip over. The core shares this allocator's event
    log, so one serving run yields one recovery/fault stream.

Deflation policy is deterministic and independent of recovery mode, so
fault-free replay digests are bit-identical with recovery compiled in
(the same contract the other recovery-capable backends honour).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .caching_allocator import Allocation, AllocatorOOM, QuotaDenied
from .chunks import CHUNK_SIZE, MB, DeviceOOM, VMMDevice, round_up
from .gmlake import GMLakeAllocator
from .metrics import AllocatorStats
from .protocol import AllocatorCapabilities
from .recovery import RecoveryConfig, recovery_enabled, run_ladder
from .registry import register


class ElasticBlock:
    """One [offset, offset+size) placement inside the elastic weight arena."""

    __slots__ = ("offset", "size", "held", "tenant")

    def __init__(self, offset: int, size: int, tenant: Optional[str] = None):
        self.offset = offset
        self.size = size
        self.held = True  # flipped by free; guards double-free
        self.tenant = tenant  # quota attribution (None = unattributed)

    def __repr__(self):
        return f"ElasticBlock(off={self.offset}, size={self.size >> 20}MB)"


@register(
    "ellm",
    AllocatorCapabilities(
        caching=True,
        stitching=False,  # weight blocks are segment-backed: no extents
        state_counts=True,
        releases_cached=True,
        recovery=True,
        elastic=True,
    ),
)
class ELLMAllocator:
    """Elastic weight arena over a VMS stitching core (module docstring).

    Public surface is the standard protocol plus ``elastic_counters``
    (inflate/deflate/spill tallies, diagnostics only — not digest
    material) and delegated ``state_counts``/``pending_unmaps`` from the
    stitching core so engine memory reports stay uniform across backends.
    """

    name = "ellm"

    #: Reservation quantum of the weight arena. Slab-sized cu_malloc keeps
    #: inflation cheap on the modeled-cost ledger (one call per slab run)
    #: and gives deflation a natural release unit.
    SLAB_BYTES = 32 * MB

    #: Requests at or above this route to the elastic arena; below it they
    #: are KV/dynamic-tail traffic for the stitching core. Two chunks is
    #: the empirical sweet spot on the recorded serving traces: anything
    #: larger packs tighter (and cheaper on the API ledger) as best-fit
    #: spans inside the arena than as stitched chunk lists, while
    #: single-chunk KV churn keeps the stitching core's reuse states hot.
    WEIGHT_THRESHOLD = 2 * CHUNK_SIZE

    #: Deflation governor: after ``DEFLATE_PATIENCE`` consecutive frees
    #: with arena utilization under ``DEFLATE_RATIO``, trailing free slabs
    #: are returned to the device.
    DEFLATE_RATIO = 0.5
    DEFLATE_PATIENCE = 16

    def __init__(
        self,
        device: VMMDevice,
        record_timeline: bool = False,
        recovery: Optional[bool] = None,
        slab_bytes: int = SLAB_BYTES,
        weight_threshold: int = WEIGHT_THRESHOLD,
        deflate_ratio: float = DEFLATE_RATIO,
        deflate_patience: int = DEFLATE_PATIENCE,
        tenant_quota_bytes: Optional[int] = None,
    ):
        if slab_bytes % CHUNK_SIZE:
            raise ValueError("slab_bytes must be a multiple of CHUNK_SIZE")
        self.device = device
        self.stats = AllocatorStats(record_timeline=record_timeline)
        self.slab_bytes = slab_bytes
        self.weight_threshold = weight_threshold
        self.deflate_ratio = deflate_ratio
        self.deflate_patience = deflate_patience

        self._recovery_on = recovery_enabled(device, recovery)
        self._recovery_cfg = RecoveryConfig()
        # the stitching core absorbs KV traffic and weight spills; adopting
        # its event log (shared with its small pool) keeps one stream
        self.core = GMLakeAllocator(device, recovery=self._recovery_on)
        self.event_log = self.core.event_log

        # elastic arena state: free spans tile [0, _top) together with the
        # live blocks; _arena_reserved is the slab-quantized device hold
        self._spans: List[List[int]] = []  # [offset, size], offset-ascending
        self._top = 0  # end of the highest live placement
        self._arena_reserved = 0
        self._arena_live = 0
        self._deflate_streak = 0
        # per-tenant arena quotas (multi-tenant isolation): while a tenant
        # context is set, its live arena bytes may not exceed the quota —
        # the over-quota request fails as AllocatorOOM (admission control
        # defers the *bursting* tenant) instead of inflating the shared
        # arena and starving everyone else's slabs. None = quotas off,
        # which keeps single-tenant behaviour (and digests) bit-identical.
        self.tenant_quota_bytes = tenant_quota_bytes
        self._tenant: Optional[str] = None
        self._tenant_arena_live: Dict[str, int] = {}
        # pressure bypass valve: set when a core-side OOM had to reclaim
        # arena slabs — from then on weight-class requests route through
        # the stitching core (which can assemble scattered chunks) so the
        # arena drains instead of re-pinning its watermark with fresh
        # placements. Cleared, with the arena released wholesale, when the
        # last elastic block frees. Only ever set on an OOM path, so
        # fault-free digests are untouched.
        self._pressure_bypass = False
        # slab indices inside the arena extent given back to the device
        # while the valve is open (interior holes). Only ever populated
        # during bypass — no new placement can land in a hole before the
        # drain completes and resets the arena.
        self._hole_slabs: set = set()
        self.elastic_counters: Dict[str, int] = {
            "inflate": 0,
            "inflated_bytes": 0,
            "deflate": 0,
            "deflated_bytes": 0,
            "spill": 0,
            "quota_denied": 0,
            "bypass": 0,
        }

    # -- accounting -----------------------------------------------------------
    @property
    def reserved_bytes(self) -> int:
        holes = len(self._hole_slabs) * self.slab_bytes
        return self._arena_reserved - holes + self.core.reserved_bytes

    @property
    def state_counts(self) -> Dict[str, int]:
        """BestFit S1–S5 tallies of the stitching core."""
        return self.core.state_counts

    @property
    def pending_unmaps(self) -> int:
        return self.core.pending_unmaps

    def drain_deferred_unmaps(self) -> int:
        return self.core.drain_deferred_unmaps()

    def release_cached(self) -> int:
        """Trailing free slabs of the arena + whatever the core can drop."""
        return self._release_trailing_slabs() + self.core.release_cached()

    # -- tenant attribution ---------------------------------------------------
    def set_tenant(self, tenant: Optional[str] = None) -> None:
        """Set (or clear) the tenant context for subsequent arena mallocs.

        Serving integrations call this around each request's allocations;
        trace replays never do, so the quota layer is invisible there.
        """
        self._tenant = tenant or None

    @property
    def tenant_arena_bytes(self) -> Dict[str, int]:
        """Live arena bytes per attributed tenant (diagnostics)."""
        return {t: b for t, b in sorted(self._tenant_arena_live.items()) if b}

    def _quota_admits(self, rsize: int) -> bool:
        if self.tenant_quota_bytes is None or self._tenant is None:
            return True
        used = self._tenant_arena_live.get(self._tenant, 0)
        return used + rsize <= self.tenant_quota_bytes

    # -- elastic arena placement ----------------------------------------------
    def _span_alloc(self, size: int) -> Optional[int]:
        """Best-fit over free spans, else the top watermark if reserved
        space covers it; None means the arena must inflate."""
        best = -1
        best_size = 0
        for i, (off, sz) in enumerate(self._spans):
            if sz >= size and (best < 0 or sz < best_size):
                best = i
                best_size = sz
                if sz == size:
                    break
        if best >= 0:
            off, sz = self._spans[best]
            if sz == size:
                self._spans.pop(best)
            else:
                self._spans[best] = [off + size, sz - size]
            return off
        if self._top + size <= self._arena_reserved:
            off = self._top
            self._top += size
            return off
        return None

    def _span_free(self, offset: int, size: int) -> None:
        spans = self._spans
        lo, hi = 0, len(spans)
        while lo < hi:  # insertion point by offset
            mid = (lo + hi) // 2
            if spans[mid][0] < offset:
                lo = mid + 1
            else:
                hi = mid
        if lo > 0 and spans[lo - 1][0] + spans[lo - 1][1] == offset:
            spans[lo - 1][1] += size
            if lo < len(spans) and offset + size == spans[lo][0]:
                spans[lo - 1][1] += spans[lo][1]
                spans.pop(lo)
            lo -= 1
        elif lo < len(spans) and offset + size == spans[lo][0]:
            spans[lo][0] = offset
            spans[lo][1] += size
        else:
            spans.insert(lo, [offset, size])
        # a span touching the watermark retracts it
        last = spans[-1]
        if last[0] + last[1] == self._top:
            self._top = last[0]
            spans.pop()

    def _inflate(self, need: int) -> bool:
        """Reserve ``need`` more arena bytes (slab-quantized by callers).
        False means the device cannot cover it — spill to the core."""
        attempt = lambda: self.device.cu_malloc(need)  # noqa: E731
        try:
            if self._recovery_on:
                run_ladder(
                    attempt,
                    [("release_core_cache", self.core.release_cached)],
                    device=self.device,
                    log=self.event_log,
                    config=self._recovery_cfg,
                    what=f"inflate:{need}",
                )
            else:
                attempt()
        except DeviceOOM:
            return False
        self._arena_reserved += need
        self.elastic_counters["inflate"] += 1
        self.elastic_counters["inflated_bytes"] += need
        return True

    def _release_trailing_slabs(self) -> int:
        """Deflate: return every whole free slab above the live watermark.

        Hole slabs in the trailing region were already given back to the
        device (bypass-mode interior release), so they shrink the extent
        without a second ``cu_free``."""
        keep = round_up(self._top, self.slab_bytes) if self._top else 0
        holes_above = {
            i for i in self._hole_slabs if i * self.slab_bytes >= keep
        }
        excess = (
            self._arena_reserved - keep - len(holes_above) * self.slab_bytes
        )
        if excess <= 0:
            if holes_above:
                self._hole_slabs -= holes_above
                self._arena_reserved = keep
            return 0
        self.device.cu_free(excess, synchronize=False)
        self._hole_slabs -= holes_above
        self._arena_reserved = keep
        self.elastic_counters["deflate"] += 1
        self.elastic_counters["deflated_bytes"] += excess
        return excess

    def _release_free_slabs(self) -> int:
        """Bypass-only interior deflate: give back every whole free slab
        *inside* the arena extent, not just the trailing ones.

        Safe only while the valve is open — no new placement can be
        handed out from the arena, so a hole can never be written to
        before the drain completes and the arena resets. This is what
        unsticks a high watermark pinned by one long-lived block: the
        free slabs below it return to the device for the stitching core
        to reuse."""
        assert self._pressure_bypass, "interior release outside bypass"
        slab = self.slab_bytes
        new = set()
        for off, sz in self._spans:
            first = (off + slab - 1) // slab
            last = (off + sz) // slab  # exclusive: whole slabs only
            for i in range(first, last):
                if i not in self._hole_slabs:
                    new.add(i)
        if not new:
            return 0
        freed = len(new) * slab
        self.device.cu_free(freed, synchronize=False)
        self._hole_slabs |= new
        self.elastic_counters["deflate"] += 1
        self.elastic_counters["deflated_bytes"] += freed
        return freed

    def _deflate_tick(self) -> None:
        """Governor: sustained low utilization releases trailing slabs."""
        if self._arena_live < int(self.deflate_ratio * self._arena_reserved):
            self._deflate_streak += 1
            if self._deflate_streak >= self.deflate_patience:
                self._release_trailing_slabs()
                self._deflate_streak = 0
        else:
            self._deflate_streak = 0

    # -- allocation -----------------------------------------------------------
    def malloc(self, size: int) -> Allocation:
        if size >= self.weight_threshold and not self._pressure_bypass:
            return self._malloc_elastic(size)
        return self._core_malloc(size)

    def _malloc_elastic(self, size: int) -> Allocation:
        rsize = round_up(size, CHUNK_SIZE)
        if not self._quota_admits(rsize):
            # isolation: the bursting tenant is the one denied; everyone
            # else's slabs (and the shared core) are untouched. QuotaDenied
            # (an AllocatorOOM) lets admission control defer the request
            # while telling eviction/retry logic the denial is tenant-local
            # and deterministic — device-side recovery cannot fix it.
            self.elastic_counters["quota_denied"] += 1
            raise QuotaDenied(
                f"ellm tenant quota: {self._tenant!r} at "
                f"{self._tenant_arena_live.get(self._tenant, 0)} of "
                f"{self.tenant_quota_bytes} arena bytes, wants {rsize} more"
            )
        off = self._span_alloc(rsize)
        if off is None:
            need = round_up(
                self._top + rsize - self._arena_reserved, self.slab_bytes
            )
            if self._inflate(need):
                off = self._span_alloc(rsize)
                assert off is not None
            else:
                # pressure spill: contiguous slabs are not available, but
                # the stitching core can assemble the block from scattered
                # physical chunks — the GMLake move, applied to elasticity
                self.elastic_counters["spill"] += 1
                return self._core_malloc(size)
        self._arena_live += rsize
        tenant = self._tenant
        if tenant is not None:
            self._tenant_arena_live[tenant] = (
                self._tenant_arena_live.get(tenant, 0) + rsize
            )
        self.stats.on_alloc(rsize, self.reserved_bytes)
        return Allocation(
            req_size=size, block_size=rsize,
            block=ElasticBlock(off, rsize, tenant), owner=self,
        )

    def _core_malloc(self, size: int) -> Allocation:
        try:
            alloc = self.core.malloc(size)  # AllocatorOOM, never DeviceOOM
        except AllocatorOOM:
            # cross-component reclaim: the core's recovery ladder cannot
            # see the arena, so a KV-side OOM with free slabs parked above
            # the arena watermark would fail while memory sits idle.
            # Force-deflate the trailing slabs and open the pressure
            # bypass valve (the arena drains instead of ratcheting), then
            # retry once; fault-free runs never reach this branch, so
            # digests are untouched.
            if not self._pressure_bypass and (
                self._arena_reserved or self._arena_live
            ):
                self._pressure_bypass = True
                self.elastic_counters["bypass"] += 1
            freed = self._release_trailing_slabs()
            if self._pressure_bypass:
                freed += self._release_free_slabs()
            if not freed:
                raise
            self.event_log.append("reclaim.deflate_arena", size=freed)
            alloc = self.core.malloc(size)
        alloc.owner = self
        # the core already counted itself; ours is the published stats
        self.stats.on_alloc(alloc.block_size, self.reserved_bytes)
        return alloc

    def free(self, alloc: Allocation) -> None:
        block = alloc.block
        if isinstance(block, ElasticBlock):
            assert block.held, "double free of elastic block"
            block.held = False
            self._span_free(block.offset, block.size)
            self._arena_live -= block.size
            if block.tenant is not None:
                self._tenant_arena_live[block.tenant] -= block.size
            if self._pressure_bypass and self._arena_live == 0:
                # drained under pressure: give the whole arena back (the
                # watermark retracted to zero with the last free) and
                # resume elastic placement from a clean slate
                self._release_trailing_slabs()
                self._pressure_bypass = False
        else:
            self.core.free(alloc)
        self._deflate_tick()
        self.stats.on_free(alloc.block_size, self.reserved_bytes)

    # -- debug / test support -------------------------------------------------
    def check_invariants(self) -> None:
        holes = len(self._hole_slabs) * self.slab_bytes
        assert 0 <= self._arena_live <= self._arena_reserved - holes
        assert self._arena_reserved % self.slab_bytes == 0
        assert self._top <= self._arena_reserved
        assert not self._hole_slabs or self._pressure_bypass, (
            "interior holes outside pressure bypass"
        )
        assert all(
            0 <= i * self.slab_bytes < self._arena_reserved
            for i in self._hole_slabs
        )
        prev_end = 0
        span_bytes = 0
        for off, sz in self._spans:
            assert sz > 0 and off >= prev_end, "spans unsorted or overlapping"
            prev_end = off + sz
            span_bytes += sz
        assert prev_end <= self._top
        assert span_bytes + self._arena_live == self._top, (
            "arena accounting leak: spans + live != watermark"
        )
        for tenant, used in self._tenant_arena_live.items():
            assert used >= 0, f"negative arena attribution for {tenant!r}"
        assert sum(self._tenant_arena_live.values()) <= self._arena_live, (
            "tenant attribution exceeds live arena bytes"
        )
        self.core.check_invariants()


__all__ = ["ELLMAllocator", "ElasticBlock"]
