"""Fragmentation / utilization accounting shared by both allocators.

Metric definitions follow the paper §5.1:

  * active memory    — bytes held by blocks currently assigned to tensors
  * reserved memory  — bytes set aside from the device (segments + chunks)
  * utilization      — peak_active / peak_reserved
  * fragmentation    — 1 - utilization
  * MemReductionRatio = (sum(reserved) - sum(gmlake_reserved)) / sum(reserved)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class AllocatorStats:
    active_bytes: int = 0
    reserved_bytes: int = 0
    peak_active: int = 0
    peak_reserved: int = 0
    n_alloc: int = 0
    n_free: int = 0
    # timeline: (event index, active, reserved) triples for trace plots
    timeline: List[tuple] = field(default_factory=list)
    record_timeline: bool = False
    #: backend-specific diagnostic counters (e.g. GMLake's round-4 fast-path
    #: hit tallies: seg_reuse / hold_fast / shell_reuse). Never part of the
    #: golden digests — purely observability for the profile harness.
    counters: Optional[dict] = None
    #: GMLake round-5 vectorized-core counters (enabled / numpy_fallback /
    #: seg_cache_builds / ref_purges / ...). Same observability-only
    #: contract as ``counters``; surfaced via ``ReplayResult.vec_counters``.
    vec_counters: Optional[dict] = None

    def __post_init__(self) -> None:
        # on_alloc/on_free run once per replayed event; when no timeline is
        # recorded, bind the branch-free fast variants so the hot path never
        # re-tests record_timeline.
        if not self.record_timeline:
            self.on_alloc = self._on_alloc_fast
            self.on_free = self._on_free_fast

    def on_alloc(self, active_delta: int, reserved: int) -> None:
        self.n_alloc += 1
        self.active_bytes += active_delta
        self.reserved_bytes = reserved
        self.peak_active = max(self.peak_active, self.active_bytes)
        self.peak_reserved = max(self.peak_reserved, self.reserved_bytes)
        if self.record_timeline:
            self.timeline.append((self.n_alloc + self.n_free, self.active_bytes, reserved))

    def on_free(self, active_delta: int, reserved: int) -> None:
        self.n_free += 1
        self.active_bytes -= active_delta
        self.reserved_bytes = reserved
        if self.record_timeline:
            self.timeline.append((self.n_alloc + self.n_free, self.active_bytes, reserved))

    def _on_alloc_fast(self, active_delta: int, reserved: int) -> None:
        self.n_alloc += 1
        active = self.active_bytes + active_delta
        self.active_bytes = active
        self.reserved_bytes = reserved
        if active > self.peak_active:
            self.peak_active = active
        if reserved > self.peak_reserved:
            self.peak_reserved = reserved

    def _on_free_fast(self, active_delta: int, reserved: int) -> None:
        self.n_free += 1
        self.active_bytes -= active_delta
        self.reserved_bytes = reserved

    @property
    def utilization(self) -> float:
        if self.peak_reserved == 0:
            return 1.0
        return self.peak_active / self.peak_reserved

    @property
    def fragmentation(self) -> float:
        return 1.0 - self.utilization


@dataclass
class AllocatorEventLog:
    """Structured allocator event stream (recovery attempts, reclamation
    rungs, spills, injected-fault observations).

    Append-only observability: never part of the golden digests. Composite
    backends (GMLake's small pool, STAlloc's fallback) share the parent's
    log so one replay yields one coherent event stream, surfaced through
    ``ServeEngine.memory_report()`` / ``ReplayResult.recovery`` / the
    fault bench.
    """

    events: List[dict] = field(default_factory=list)
    counts: Dict[str, int] = field(default_factory=dict)

    def append(self, kind: str, **detail) -> None:
        self.counts[kind] = self.counts.get(kind, 0) + 1
        ev = {"kind": kind}
        ev.update(detail)
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        """Drop accumulated events/counts *in place* — composite backends
        share one log object, so reassignment would silently fork the
        stream. The serving engine calls this on restore: post-restore
        memory reports describe the new life only."""
        self.events.clear()
        self.counts.clear()

    def summary(self) -> dict:
        return {"n_events": len(self.events), "counts": dict(self.counts)}


def mem_reduction_ratio(reserved: List[int], gmlake_reserved: List[int]) -> float:
    """Arithmetic-average memory reduction across workloads (paper §5.1)."""
    tot = sum(reserved)
    if tot == 0:
        return 0.0
    return (tot - sum(gmlake_reserved)) / tot


@dataclass
class ReplayResult:
    """One allocator x one trace."""

    name: str
    stats: AllocatorStats
    model_cost: float  # modeled device-API cost (cuMalloc units)
    wall_seconds: float  # host-side data-structure time, measured
    oom: bool = False
    oom_at_event: Optional[int] = None
    state_counts: Optional[dict] = None  # GMLake S1..S5 hit counts
    #: ``AllocatorEventLog.summary()`` when the backend logged recovery /
    #: reclamation events during the replay; None on a quiet run
    recovery: Optional[dict] = None
    #: snapshot of the backend's vectorized-core counters (GMLake round 5:
    #: enabled / numpy_fallback / seg_cache_builds / ref_purges / ...);
    #: None for backends without a vectorized core
    vec_counters: Optional[dict] = None
    #: planned-vs-spilled routing tallies of the hybrid backend
    #: (planned_allocs/planned_bytes/spilled_allocs/spilled_bytes);
    #: None for backends without a planned/spill split
    hybrid_counters: Optional[dict] = None

    @property
    def utilization(self) -> float:
        return self.stats.utilization

    @property
    def fragmentation(self) -> float:
        return self.stats.fragmentation

    @property
    def reserved_gb(self) -> float:
        return self.stats.peak_reserved / (1024**3)

    @property
    def active_gb(self) -> float:
        return self.stats.peak_active / (1024**3)
